"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b \
        [--steps 5] [--batch 2] [--seq 64] [--algorithm profe]

Runs real ProFe training steps (teacher+student joint, Eq. 8/9) on the
selected architecture.  On this CPU container it uses the reduced
(smoke) variant by default so the loop actually runs; ``--full-config``
switches to the assigned full config (only feasible on a real TPU mesh,
where the same code path runs under ``make_production_mesh()``).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FederationConfig, TrainConfig, get_config
from repro.core.profe import init_node_state, make_profe_step
from repro.data import make_token_dataset
from repro.models import derive_student
from repro.optim import make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.smoke()
    student_cfg = derive_student(cfg)
    fed = FederationConfig()
    print(f"teacher {cfg.name}: {cfg.num_layers}L d={cfg.d_model}")
    print(f"student {student_cfg.name}: {student_cfg.num_layers}L "
          f"d_ff={student_cfg.d_ff}")

    opt = make_optimizer(cfg.optimizer, args.lr)
    state = init_node_state(cfg, student_cfg, jax.random.PRNGKey(0), opt, opt,
                            cfg.n_proto_classes)
    step = make_profe_step(cfg, student_cfg, fed, opt, opt, remat=False)

    data = make_token_dataset(0, args.steps * args.batch, args.seq,
                              cfg.vocab_size, cfg.n_proto_classes)
    t0 = time.time()
    for i in range(args.steps):
        sl = slice(i * args.batch, (i + 1) * args.batch)
        batch = {
            "tokens": jnp.asarray(data["tokens"][sl]),
            "labels": jnp.asarray(data["labels"][sl]),
            "domains": jnp.asarray(data["domains"][sl]),
        }
        if cfg.family == "vlm":
            batch["image_embed"] = jnp.zeros(
                (args.batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            batch["audio_embed"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        state, metrics = step(state, batch, teacher_on=True)
        print(f"step {i}: loss_s={float(metrics['loss_s']):.4f} "
              f"loss_t={float(metrics['loss_t']):.4f} "
              f"({time.time() - t0:.1f}s)", flush=True)

    if args.checkpoint:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.checkpoint, state.student,
                        metadata={"arch": args.arch, "steps": args.steps})
        print(f"saved student -> {args.checkpoint}")


if __name__ == "__main__":
    main()
