"""Static analysis of compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE, which
undercounts scan-over-layers models by ~the layer count.  This analyzer
walks the computation call graph, multiplying loop bodies by their
``known_trip_count`` (XLA prints it in the while op's backend_config),
and produces per-device:

* FLOPs          — 2·M·N·K for every dot (+conv approximation)
* HBM bytes      — operands+results of top-level ops (fusion-internal
                   values stay in registers/VMEM and are not counted)
* collective bytes, by kind (ring conventions: all-reduce counts 2x its
  operand, all-gather counts its gathered output)

All shapes in the partitioned module are per-device shard shapes, so
results divide by per-chip peaks directly.

Per-axis attribution
--------------------
Pass ``mesh_shape=(("pod", 8), ("data", 2), ("model", 1))`` (the mesh
axis order; partition ids linearize the device array row-major, which is
how ``fed_mesh`` builds it) and every collective is additionally
classified by WHICH mesh axes its participants span:

* ``collective-permute``: each ``source_target_pairs`` entry is a
  directed copy of the per-device operand; the pair's axis is where the
  source and target coordinates differ.
* gather/reduce collectives: ``replica_groups`` (explicit ``{{0,1},..}``
  or iota ``[G,S]<=[dims]T(perm)`` form) members are unraveled to mesh
  coordinates; the group's axes are the coordinates that vary inside it.

``Cost.axis_coll[axis][kind]`` then holds SYSTEM-TOTAL bytes for that
axis (per-device convention bytes x participating devices) — a permute
that crosses only inner axes lands under ``"data"``, never inflating the
``pod`` wire budget, so multi-axis runs can gate per-node pod bytes
exactly instead of double-counting cross-axis collectives.  Collectives
whose participants vary on several axes land under a compound key like
``"data+pod"``.  Without ``mesh_shape`` the analyzer behaves exactly as
before (``axis_coll`` stays empty).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\w*?)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_CALL_RE = re.compile(r"(?:body|calls|to_apply|condition)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{((?:\{[\d,]*\},?)*)\}")
_IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[([\d,]+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _nbytes(shapes: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)
    # axis -> kind -> SYSTEM-TOTAL bytes (only filled when analyze_hlo
    # was given a mesh_shape); axis may be a compound "data+pod" key
    axis_coll: Dict[str, Dict[str, float]] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v * mult
        for ax, kinds in other.axis_coll.items():
            dst = self.axis_coll.setdefault(ax, {})
            for k, v in kinds.items():
                dst[k] = dst.get(k, 0.0) + v * mult

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())

    def axis_total(self, axis: str) -> float:
        """System-total collective bytes whose participants span ``axis``
        (compound "a+b" keys containing the axis are included, so a
        collective crossing pod AND an inner axis still counts against
        the pod budget instead of silently escaping it)."""
        total = 0.0
        for key, kinds in self.axis_coll.items():
            if axis in key.split("+"):
                total += sum(kinds.values())
        return total


def _iota_groups(dims_txt: str, src_txt: str,
                 perm_txt: Optional[str]) -> List[List[int]]:
    """Expand XLA's iota replica-group form ``[G,S]<=[d0,d1]T(p)``."""
    import numpy as np
    dims = [int(d) for d in dims_txt.split(",") if d]
    src = [int(d) for d in src_txt.split(",") if d]
    ids = np.arange(int(np.prod(src))).reshape(src)
    if perm_txt:
        ids = ids.transpose([int(p) for p in perm_txt.split(",") if p])
    return ids.reshape(dims).tolist()


def _collective_participants(line: str, n_devices: int
                             ) -> Tuple[str, List[List[int]], int]:
    """(structure, groups, n_participants) for one collective line.

    structure is "pairs" (collective-permute source/target copies, each
    inner list is ``[src, dst]``) or "groups" (replica groups).  An
    absent / empty replica_groups attribute means one group of every
    device.
    """
    mp = _PAIRS_RE.search(line)
    if mp:
        pairs = [[int(a), int(b)]
                 for a, b in re.findall(r"\{(\d+),(\d+)\}", mp.group(1))]
        return "pairs", pairs, len(pairs)
    mi = _IOTA_GROUPS_RE.search(line)
    if mi:
        groups = _iota_groups(*mi.groups())
        return "groups", groups, sum(len(g) for g in groups)
    mg = _GROUPS_RE.search(line)
    if mg and mg.group(1):
        groups = [[int(x) for x in g.split(",") if x]
                  for g in re.findall(r"\{([\d,]*)\}", mg.group(1))]
        groups = [g for g in groups if g]
        if groups:
            return "groups", groups, sum(len(g) for g in groups)
    return "groups", [list(range(n_devices))], n_devices


def _axis_key(members: Sequence[int], axes: Sequence[Tuple[str, int]]) -> str:
    """Mesh axes on which ``members`` (linear partition ids) differ."""
    sizes = [s for _, s in axes]
    coords = []
    for dev in members:
        c, rem = [], dev
        for s in reversed(sizes):
            c.append(rem % s)
            rem //= s
        coords.append(tuple(reversed(c)))
    varying = sorted({axes[i][0] for i in range(len(axes))
                      for a, b in zip(coords, coords[1:]) if a[i] != b[i]})
    return "+".join(varying) if varying else "self"


def _split_computations(text: str) -> Tuple[Dict[str, List[str]], str]:
    comps: Dict[str, List[str]] = {}
    entry = ""
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def _dot_flops(line: str, symtab: Dict[str, List[Tuple[str, List[int]]]]) -> float:
    result = _shape_list(line.split(" dot(")[0])
    if not result:
        return 0.0
    _, rdims = result[-1]
    relems = 1
    for d in rdims:
        relems *= d
    # contracted size from lhs operand shape + lhs_contracting_dims
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    ops = re.findall(r"%([\w.\-]+)", line.split("dot(")[1].split(")")[0])
    k = 1
    if mc and ops:
        lhs_shape = symtab.get(ops[0])
        if lhs_shape:
            dims = lhs_shape[-1][1]
            for ci in mc.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * relems * k


def _conv_flops(line: str, symtab) -> float:
    result = _shape_list(line.split(" convolution(")[0])
    if not result:
        return 0.0
    _, rdims = result[-1]
    relems = 1
    for d in rdims:
        relems *= d
    ops = re.findall(r"%([\w.\-]+)",
                     line.split("convolution(")[1].split(")")[0])
    if len(ops) >= 2 and ops[1] in symtab:
        kdims = symtab[ops[1]][-1][1]
        kelems = 1
        for d in kdims:
            kelems *= d
        # dim_labels ...io-> : output-feature dim is 'o'
        mdl = re.search(r"dim_labels=\w+_(\w+)->", line)
        ofeat = kdims[-1]
        if mdl:
            labels = mdl.group(1)
            if "o" in labels:
                ofeat = kdims[labels.index("o")]
        return 2.0 * relems * (kelems / max(ofeat, 1))
    return 0.0


def analyze_hlo(text: str,
                mesh_shape: Optional[Sequence[Tuple[str, int]]] = None
                ) -> Cost:
    n_devices = 1
    if mesh_shape is not None:
        mesh_shape = tuple(mesh_shape)
        for _, s in mesh_shape:
            n_devices *= s
    comps, entry = _split_computations(text)
    if not entry:
        # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    memo: Dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()  # cycle guard
        lines = comps.get(name, [])
        symtab: Dict[str, List[Tuple[str, List[int]]]] = {}
        cost = Cost()
        for line in lines:
            s = line.strip()
            m = _INSTR_RE.match(s)
            if not m:
                continue
            iname, result_txt, op = m.groups()
            symtab[iname] = _shape_list(result_txt)

            if op == "dot":
                cost.flops += _dot_flops(s, symtab)
                cost.bytes += _nbytes(symtab[iname])
            elif op == "convolution":
                cost.flops += _conv_flops(s, symtab)
                cost.bytes += _nbytes(symtab[iname])
            elif op == "while":
                trip = 1
                mt = _TRIP_RE.search(s)
                if mt:
                    trip = int(mt.group(1))
                for child in _CALL_RE.findall(s):
                    cost.add(comp_cost(child), trip)
            elif op == "conditional":
                mb = _BRANCHES_RE.search(s)
                if mb:
                    kids = [c.strip().lstrip("%")
                            for c in mb.group(1).split(",")]
                    costs = [comp_cost(c) for c in kids if c in comps]
                    if costs:
                        biggest = max(costs, key=lambda c: c.flops + c.bytes)
                        cost.add(biggest)
            elif op in ("fusion", "call", "map", "reduce", "reduce-window",
                        "sort", "scatter", "select-and-scatter"):
                # fusion/call bodies: FLOPs inside count; their internal
                # values don't touch HBM (fusion) so bytes = call-site IO
                for child in _CALL_RE.findall(s):
                    child_cost = comp_cost(child)
                    cost.flops += child_cost.flops
                    for k, v in child_cost.coll.items():
                        cost.coll[k] = cost.coll.get(k, 0.0) + v
                    for ax, kinds in child_cost.axis_coll.items():
                        dst = cost.axis_coll.setdefault(ax, {})
                        for k, v in kinds.items():
                            dst[k] = dst.get(k, 0.0) + v
                cost.bytes += _nbytes(symtab[iname]) + _operand_bytes(s, symtab, op)
            else:
                base = op.replace("-start", "")
                if base in _COLLECTIVES and not op.endswith("-done"):
                    ob = _operand_bytes(s, symtab, op)
                    rb = _nbytes(symtab[iname])
                    if base == "all-gather":
                        nb = rb
                    elif base == "all-reduce":
                        nb = 2 * ob
                    else:
                        nb = ob
                    cost.coll[base] = cost.coll.get(base, 0.0) + nb
                    cost.coll_counts[base] = cost.coll_counts.get(base, 0.0) + 1
                    cost.bytes += ob + rb
                    if mesh_shape is not None:
                        kind, parts, _ = _collective_participants(s, n_devices)
                        if kind == "pairs":
                            # each source->target copy moves the operand
                            for pair in parts:
                                key = _axis_key(pair, mesh_shape)
                                dst = cost.axis_coll.setdefault(key, {})
                                dst[base] = dst.get(base, 0.0) + ob
                        else:
                            # convention bytes are per participating device
                            for group in parts:
                                key = _axis_key(group, mesh_shape)
                                dst = cost.axis_coll.setdefault(key, {})
                                dst[base] = dst.get(base, 0.0) + nb * len(group)
                elif op in ("parameter", "constant", "iota", "tuple",
                            "get-tuple-element", "bitcast", "reshape",
                            "broadcast", "after-all", "partition-id"):
                    pass  # no HBM traffic attributed
                else:
                    # top-level elementwise / copy / dynamic-slice etc.
                    cost.bytes += _nbytes(symtab[iname]) + _operand_bytes(s, symtab, op)
        memo[name] = cost
        return cost

    return comp_cost(entry) if entry else Cost()


def _operand_bytes(line: str, symtab, op: Optional[str] = None) -> int:
    """Bytes of the instruction's operands.  Anchored on ``op(`` when the
    op name is known — result tuple shapes and ``metadata={op_name=
    "jit(...)"}`` attributes both contain parens, so position-based
    splitting misparses the operand list."""
    if op is not None:
        idx = line.find(f" {op}(")
        inside = line[idx + len(op) + 2:].split(")")[0] if idx >= 0 else ""
    else:
        inside = line.split("(", 2)[-1].split(")")[0] if "(" in line else ""
    total = 0
    for opname in re.findall(r"%([\w.\-]+)", inside):
        if opname in symtab:
            total += _nbytes(symtab[opname])
    return total
