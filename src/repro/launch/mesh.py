"""Production mesh construction.

Functions, not module-level constants — importing this module never
touches jax device state (the dry-run sets the 512-device XLA flag before
any jax initialization, see dryrun.py).

Mesh semantics (DESIGN.md §2):
  pod   — federation node (ProFe gossip crosses this axis only)
  data  — in-node batch/FSDP parallelism
  model — in-node tensor parallelism
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU tests (axes exist, size 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_axis_names(mesh) -> tuple:
    return tuple(mesh.axis_names)


def dp_axes(mesh):
    """Axes the training batch shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
