"""Production serving launcher: batched autoregressive decoding with a
KV cache (or constant recurrent state) for any assigned architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
        [--batch 4] [--prompt-len 16] [--tokens 32] [--rolling]

On this CPU container the reduced (smoke) config runs real decode steps;
on a TPU mesh the same ``serve_step`` is what the dry-run lowers for
``decode_32k`` / ``long_500k`` (see launch/dryrun.py), with the KV cache
sharded per repro/sharding.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import get_config
from repro.models import decode_step, init_cache, init_params
from repro.models.model import build_memory


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--rolling", action="store_true",
                    help="sliding-window KV (the long_500k serving path)")
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.smoke()
    print(f"serving {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"family={cfg.family} subquadratic={cfg.subquadratic}")

    rng = jax.random.PRNGKey(0)
    params = init_params(cfg, rng)
    batch = {"tokens": jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["image_embed"] = jnp.zeros(
            (args.batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["audio_embed"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    memory = build_memory(cfg, params, batch)

    total = args.prompt_len + args.tokens
    cache_len = cfg.sliding_window_serve if args.rolling else total
    cache = init_cache(cfg, args.batch, cache_len, jnp.bfloat16)
    step = jax.jit(lambda p, t, i, c: decode_step(
        cfg, p, t, i, c, memory, rolling=args.rolling))

    tok = batch["tokens"][:, :1]
    t0 = time.time()
    out_tokens = []
    for i in range(total - 1):
        logits, cache = step(params, tok, jnp.int32(i), cache)
        if i + 1 < args.prompt_len:
            tok = batch["tokens"][:, i + 1:i + 2]
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out_tokens.append(tok)
    dt = time.time() - t0
    print(f"decoded {len(out_tokens)} tokens x batch {args.batch} "
          f"in {dt:.1f}s ({len(out_tokens) * args.batch / dt:.1f} tok/s CPU)")


if __name__ == "__main__":
    main()
