"""Checkpointing: pytree <-> .npz with key-path flattening.

Host-side (numpy) serialization; restoring onto a sharded mesh goes
through ``jax.device_put`` with the target sharding at the call site.
Works for params, optimizer states, and federation node states alike.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


_SEP = "/"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def save_checkpoint(path: str, tree, *, metadata: Dict[str, Any] | None = None):
    """Write tree to ``path`` (.npz) + structure sidecar (.json)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    treedef = jax.tree_util.tree_structure(tree)
    side = {"treedef": str(treedef), "metadata": metadata or {}}
    with open(_sidecar(path), "w") as f:
        json.dump(side, f)


def _sidecar(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".meta.json"


def load_checkpoint(path: str, like_tree) -> Any:
    """Restore into the structure of ``like_tree`` (keys must match)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    flat_like = _flatten(like_tree)
    missing = set(flat_like) - set(npz.files)
    extra = set(npz.files) - set(flat_like)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")
    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    paths = [
        _SEP.join(_path_str(p) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like_tree)[0]
    ]
    new_leaves = []
    for key, leaf in zip(paths, leaves_like):
        arr = npz[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
