#!/usr/bin/env bash
# The full verification gate, in one command (see README / ROADMAP):
#
#   1. tier-1 pytest (conftest forces 8 virtual host devices so the
#      mesh-marked ppermute tests run inside the CPU suite)
#   2. the tier-1-adjacent perf/wire gate: re-measures the jitted round
#      against BENCH_round_step.json and the wire exchange against
#      BENCH_wire_exchange.json (codec ms within threshold, per-node
#      collective bytes EXACT per wire spec).  When the committed
#      baseline carries per-phase rows (round_step.py --phases), the
#      single-pass gate rides along: fused round beats exact at the
#      largest N, fused Eq. 3 marginal <= 0.5x the exact pass, the
#      parameter-plane fused clip+update beats the per-leaf optimizer
#      at every committed N, fresh exact proto phase within threshold.
#
#   scripts/verify.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"
python benchmarks/check_regression.py
